"""ZoneEngine: the device state machine as a pure pytree + scan programs.

The legacy :class:`repro.core.device_legacy.LegacyZNSDevice` executes every
WRITE/FINISH/RESET as a stateful Python call with a host->JAX round-trip
per allocation.  This module inverts that ownership: **all** device state
lives in a :class:`DeviceState` pytree of ``jnp`` arrays, and every zone
command is a pure jit-compiled transition

    apply_op(state, op_row) -> (state, OpTrace)

so an encoded ``(n_ops, 4)`` int32 *op program* runs in a single
``lax.scan`` (:func:`run_program`) with no per-op host round-trips, and a
batch of programs (e.g. a DLWA occupancy sweep) runs in one vmapped scan
(:func:`run_programs`).  Semantics are bit-exact with the legacy device --
the differential property tests in ``tests/test_engine_diff.py`` replay
random op sequences through both.

Op encoding (all int32): ``[opcode, zone, n_pages, flags]`` with flags
bit0 = host write (0 -> dummy/device-internal write).  Rows may carry
extra trailing columns (the fleet layer appends a *tenant* tag in column
4, see :mod:`repro.fleet.tenants`); the engine only reads the first four.
Illegal ops (FULL write, overflow, allocation failure, active-zone limit)
never raise: they apply exactly the partial effects the legacy device
leaves behind after its ``RuntimeError`` (e.g. an overflowing write still
opens the zone) and report ``ok=0`` in the trace.

Static configuration is a frozen hashable :class:`EngineConfig`, so the
jitted transitions are compile-cached *per device geometry/spec*, not per
engine instance.  A small subset of the config -- the knobs that affect
*values* but not *array shapes* -- can additionally be overridden per
call (and per batch lane) with a traced :class:`DynConfig`: effective
zone capacity in pages, the active-zone limit, the addressable zone
count, and the allocator's wear-awareness.  This is what lets a single
``run_programs`` dispatch batch a *heterogeneous* fleet: every lane
shares the padded static shapes of the largest geometry while its
``DynConfig`` selects the member's effective geometry/allocator (see
:mod:`repro.fleet`).

Units: ``n_pages``/``zone_pages``/``wp`` count flash pages; ``wear`` and
``block_erases`` count erase-block erasures; zones and elements are
indexed densely from 0.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import zns
from repro.core.alloc_exact import (AVAIL_ALLOCATED, AVAIL_FREE,
                                    AVAIL_INVALID, AVAIL_VALID)
from repro.core.elements import (ElementKind, ElementLayout, ElementSpec,
                                 build_layout, elements_per_zone,
                                 groups_per_zone)
from repro.core.geometry import FlashGeometry, ZoneGeometry

# ----------------------------------------------------------------------- #
# op + zone-state encodings
# ----------------------------------------------------------------------- #
OP_NOP, OP_ALLOC, OP_WRITE, OP_FINISH, OP_RESET, OP_READ = range(6)
F_HOST = 1  # flags bit0: host (vs dummy) write

ZONE_EMPTY, ZONE_OPEN, ZONE_FULL = 0, 1, 2

_BIG = 2**30  # sentinel wear for unavailable slots (matches allocator.py)


# ----------------------------------------------------------------------- #
# static config + state pytree
# ----------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Hashable static description of one device geometry/element spec.

    All fields are compile-time constants (they determine array shapes
    and loop structure).  Page-unit fields: ``pages_per_block``,
    ``zone_pages``, ``pages_per_element``; block-unit:
    ``blocks_per_element``; the rest count elements / groups / zones /
    LUN columns.  The *value-only* subset (``zone_pages``,
    ``max_active``, ``n_zones``, ``wear_aware``) can be shadowed per
    call by a :class:`DynConfig`.
    """

    kind: ElementKind
    chunk: int
    wear_aware: bool
    n_elements: int
    n_groups: int
    per_group: int
    luns_per_group: int
    take: int            # elements taken per winning group
    zone_groups: int     # winning groups per zone
    slot_stride: int     # slot = rank * slot_stride + window_position
    n_slots: int
    parallelism: int
    n_segments: int
    pages_per_block: int
    zone_pages: int
    pages_per_element: int
    blocks_per_element: int
    n_zones: int
    max_active: int
    n_channels: int

    @property
    def spec(self) -> ElementSpec:
        return ElementSpec(self.kind, self.chunk)


class DeviceState(NamedTuple):
    """The whole device as a pytree.  Element arrays carry one trailing
    *scratch* slot (index ``n_elements``) absorbing masked scatters."""

    elem_wear: jax.Array    # (n_elements + 1,) i32
    elem_avail: jax.Array   # (n_elements + 1,) i32
    elem_pages: jax.Array   # (n_elements + 1,) i32
    elem_zone: jax.Array    # (n_elements + 1,) i32
    zone_state: jax.Array   # (n_zones,) i32
    zone_wp: jax.Array      # (n_zones,) i32
    zone_host_wp: jax.Array  # (n_zones,) i32
    zone_elems: jax.Array   # (n_zones, n_slots) i32, -1 = unmapped/released
    zone_cols: jax.Array    # (n_zones, parallelism) i32 zone column -> LUN
    rr_next: jax.Array      # () i32 round-robin window start
    n_active: jax.Array     # () i32 OPEN zone count
    host_pages: jax.Array   # () i32
    dummy_pages: jax.Array  # () i32
    block_erases: jax.Array  # () i32
    alloc_calls: jax.Array  # () i32


class OpTrace(NamedTuple):
    """Per-op trace slice: enough to rebuild IO streams host-side."""

    op: jax.Array          # () i32
    zone: jax.Array        # () i32
    ok: jax.Array          # () bool
    wp_before: jax.Array   # () i32
    wp_after: jax.Array    # () i32
    host_delta: jax.Array  # () i32
    dummy_delta: jax.Array  # () i32
    erase_delta: jax.Array  # () i32
    elems: jax.Array       # (n_slots,) i32  zone slot row *after* the op
    cols: jax.Array        # (parallelism,) i32 zone column -> LUN


class DynConfig(NamedTuple):
    """Traced (per-call / per-batch-lane) overrides of the value-only
    :class:`EngineConfig` fields.

    Every field is a rank-0 array (or, under ``run_programs``, a
    ``(n_programs,)`` vector -- one value per lane):

    * ``zone_pages``  -- () i32, effective zone capacity in *pages*.
      Must be ``<= cfg.zone_pages``; a smaller value emulates a
      shorter-zone geometry (fewer segments) on the padded static
      shapes: writes seal at the effective capacity and FINISH frees the
      never-touched tail elements, so metrics match a device built with
      the smaller geometry outright (tested).  Exact for every element kind
      whose per-element page capacity is segment-count-independent
      (BLOCK / VCHUNK / HCHUNK / SUPERBLOCK); FIXED elements *are* the
      whole static zone, so FIXED lanes must keep the full capacity.
    * ``max_active``  -- () i32, open/active-zone limit.
    * ``n_zones``     -- () i32, addressable zones (``<= cfg.n_zones``);
      op rows are clipped into ``[0, n_zones)``.
    * ``wear_aware``  -- () bool, allocator policy: lowest-(wear, col)
      selection when true, first-fit by column when false.
    """

    zone_pages: jax.Array
    max_active: jax.Array
    n_zones: jax.Array
    wear_aware: jax.Array


def make_dyn(cfg: EngineConfig, *, zone_pages: Optional[int] = None,
             max_active: Optional[int] = None, n_zones: Optional[int] = None,
             wear_aware: Optional[bool] = None) -> DynConfig:
    """A :class:`DynConfig` defaulting every field to ``cfg``'s value."""
    i32 = jnp.int32
    return DynConfig(
        zone_pages=jnp.asarray(
            cfg.zone_pages if zone_pages is None else zone_pages, i32),
        max_active=jnp.asarray(
            cfg.max_active if max_active is None else max_active, i32),
        n_zones=jnp.asarray(
            cfg.n_zones if n_zones is None else n_zones, i32),
        wear_aware=jnp.asarray(
            cfg.wear_aware if wear_aware is None else wear_aware, bool),
    )


def stack_dyn(dyns: Sequence[DynConfig]) -> DynConfig:
    """Stack per-lane :class:`DynConfig`\\ s along a leading batch axis
    (the shape ``run_programs`` consumes for a heterogeneous batch)."""
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *dyns)


def _slot_stride(spec: ElementSpec, parallelism: int) -> int:
    if spec.kind is ElementKind.BLOCK:
        return parallelism
    if spec.kind is ElementKind.VCHUNK:
        return parallelism // spec.chunk
    if spec.kind is ElementKind.SUPERBLOCK:
        return 1
    if spec.kind is ElementKind.HCHUNK:
        return parallelism
    if spec.kind is ElementKind.FIXED:
        return 1
    raise ValueError(spec.kind)


def make_config(flash: FlashGeometry, zone_geom: ZoneGeometry,
                spec: ElementSpec, *, max_active: int = 14,
                wear_aware: Optional[bool] = None
                ) -> Tuple[EngineConfig, ElementLayout]:
    layout = build_layout(flash, spec, zone_geom)
    elems = elements_per_zone(layout, zone_geom)
    zgroups = groups_per_zone(layout, zone_geom)
    cfg = EngineConfig(
        kind=spec.kind,
        chunk=spec.chunk,
        wear_aware=(spec.kind is not ElementKind.FIXED
                    if wear_aware is None else wear_aware),
        n_elements=layout.n_elements,
        n_groups=layout.n_groups,
        per_group=layout.n_elements // layout.n_groups,
        luns_per_group=layout.luns_per_group,
        take=elems // zgroups,
        zone_groups=zgroups,
        slot_stride=_slot_stride(spec, zone_geom.parallelism),
        n_slots=zns.n_slots(spec, zone_geom.parallelism,
                            zone_geom.n_segments),
        parallelism=zone_geom.parallelism,
        n_segments=zone_geom.n_segments,
        pages_per_block=flash.pages_per_block,
        zone_pages=zone_geom.zone_pages(flash),
        pages_per_element=layout.pages_per_element,
        blocks_per_element=layout.blocks_per_element,
        n_zones=flash.n_blocks // zone_geom.blocks_per_zone,
        max_active=max_active,
        n_channels=flash.n_channels,
    )
    return cfg, layout


def init_state(cfg: EngineConfig) -> DeviceState:
    n = cfg.n_elements + 1  # + scratch slot
    i32 = jnp.int32
    return DeviceState(
        elem_wear=jnp.zeros(n, i32),
        elem_avail=jnp.full(n, AVAIL_FREE, i32),
        elem_pages=jnp.zeros(n, i32),
        elem_zone=jnp.full(n, -1, i32),
        zone_state=jnp.full(cfg.n_zones, ZONE_EMPTY, i32),
        zone_wp=jnp.zeros(cfg.n_zones, i32),
        zone_host_wp=jnp.zeros(cfg.n_zones, i32),
        zone_elems=jnp.full((cfg.n_zones, cfg.n_slots), -1, i32),
        zone_cols=jnp.zeros((cfg.n_zones, cfg.parallelism), i32),
        rr_next=jnp.zeros((), i32),
        n_active=jnp.zeros((), i32),
        host_pages=jnp.zeros((), i32),
        dummy_pages=jnp.zeros((), i32),
        block_erases=jnp.zeros((), i32),
        alloc_calls=jnp.zeros((), i32),
    )


# ----------------------------------------------------------------------- #
# pure selection helpers (bit-exact with allocator.py / device_legacy.py)
# ----------------------------------------------------------------------- #
def _rr_mask(cfg: EngineConfig, start: jax.Array) -> jax.Array:
    idx = (start + jnp.arange(cfg.zone_groups, dtype=jnp.int32)) % cfg.n_groups
    return jnp.zeros(cfg.n_groups, bool).at[idx].set(True)


def _take_lowest(cfg: EngineConfig, w2, a2, eligible, by_wear, take_eff):
    """Per-eligible-group ``take`` lowest-(wear, col) available elements.

    One ``top_k`` over the unique composite key ``wear * per_group + col``
    reproduces the legacy stable argsort selection *and* its arrange
    order (within a group, selected elements ranked by wear then column)
    without full sorts -- the scan's hot path.  ``by_wear`` may be a
    traced () bool (the :class:`DynConfig` allocator axis); false is the
    wear-oblivious first-fit (selection key = column alone).
    ``take_eff`` (traced, ``<= cfg.take``) is how many of the selected
    elements the zone will actually claim (fewer under an effective-
    capacity override): feasibility only requires that many.

    Returns (cols (n_groups, take) ordered ascending by (wear, col),
    feasible).  Valid only where ``eligible``; overflow-safe while wear
    stays below ``2**30 / per_group`` (far beyond any simulated churn).
    """
    free = (a2 == AVAIL_FREE) | (a2 == AVAIL_INVALID)
    free = free & eligible[:, None]
    col = jnp.arange(cfg.per_group, dtype=jnp.int32)[None, :]
    composite = w2 * cfg.per_group + col
    key = jnp.where(free, jnp.where(by_wear, composite, col), _BIG)
    negv, cols = jax.lax.top_k(-key, cfg.take)
    # the take_eff-th smallest key must be a real element
    kth = jnp.take(negv, take_eff - 1, axis=1)
    got_all = (-kth) < _BIG
    feasible = jnp.all(got_all | ~eligible)
    cols = cols.astype(jnp.int32)
    # whatever key selected the elements, the legacy ``_arrange`` ranks
    # them by (wear, col) when assigning zone slots.  On the wear-aware
    # path the top_k output is already in that order, so the reorder is
    # an identity there (and lets ``by_wear`` stay traced).  Non-free
    # filler (top_k rows with fewer than ``take`` free elements) must
    # keep sorting last, or an in-use element could be reordered into
    # the claimed take_eff prefix and stolen from its zone.
    sel_free = jnp.take_along_axis(free, cols, axis=1)
    sel_key = jnp.where(
        sel_free,
        jnp.take_along_axis(w2, cols, axis=1) * cfg.per_group + cols,
        _BIG)
    order = jnp.argsort(sel_key, axis=1, stable=True)
    cols = jnp.take_along_axis(cols, order, axis=1)
    return cols, feasible


def _cheapest_groups(cfg: EngineConfig, w2, a2, take_eff) -> jax.Array:
    ok = (a2 == AVAIL_FREE) | (a2 == AVAIL_INVALID)
    keyed = jnp.where(ok, w2.astype(jnp.float32), jnp.inf)
    part = -jax.lax.top_k(-keyed, cfg.take)[0]  # take smallest per row
    rank = jnp.arange(cfg.take, dtype=jnp.int32)[None, :]
    cost = jnp.where(rank < take_eff, part, 0.0).sum(axis=1)
    order = jnp.argsort(cost, stable=True)[: cfg.zone_groups]
    return jnp.zeros(cfg.n_groups, bool).at[order].set(True)


def _where_state(pred, new: DeviceState, old: DeviceState) -> DeviceState:
    return jax.tree_util.tree_map(
        lambda a, b: jnp.where(pred, a, b), new, old)


# ----------------------------------------------------------------------- #
# transitions
# ----------------------------------------------------------------------- #
def _alloc(cfg: EngineConfig, dyn: DynConfig, state: DeviceState,
           zone: jax.Array) -> Tuple[DeviceState, jax.Array]:
    """ALLOC a zone's elements (legacy ``_allocate_zone``).  Caller guards
    on the zone being EMPTY; this applies the selection + deferred erase."""
    n = cfg.n_elements
    limit_ok = state.n_active < dyn.max_active

    if cfg.kind is ElementKind.FIXED:
        wear = state.elem_wear[:n]
        avail = state.elem_avail[:n]
        free = (avail == AVAIL_FREE) | (avail == AVAIL_INVALID)
        key = jnp.where(
            free,
            jnp.where(dyn.wear_aware, wear,
                      jnp.arange(n, dtype=jnp.int32)),
            _BIG)
        e = jnp.argmin(key).astype(jnp.int32)
        feasible = free.any()
        band = e % cfg.n_groups
        cols_row = (band * cfg.parallelism
                    + jnp.arange(cfg.parallelism, dtype=jnp.int32))
        elems_row = jnp.full((cfg.n_slots,), e, jnp.int32)
        rr_next = state.rr_next
    else:
        pg = cfg.per_group
        w2 = state.elem_wear[:n].reshape(cfg.n_groups, pg)
        a2 = state.elem_avail[:n].reshape(cfg.n_groups, pg)
        # effective-capacity override (DynConfig): a shrunk lane claims
        # only the slots its capacity can reach, so its element set --
        # and therefore wear / deferred-erase accounting -- is exactly
        # the one a device built with the smaller geometry would pick
        # (slot layouts are uniform across groups for whole-segment
        # capacities, so the per-group claim count is a single scalar)
        n_slots_eff = dyn.zone_pages // cfg.pages_per_element
        take_eff = jnp.clip(n_slots_eff // max(1, cfg.slot_stride),
                            1, cfg.take).astype(jnp.int32)
        elig1 = _rr_mask(cfg, state.rr_next)
        cols1, f1 = _take_lowest(cfg, w2, a2, elig1, dyn.wear_aware,
                                 take_eff)

        # round-robin window exhausted: cheapest feasible groups instead
        # (the legacy fallback always uses the wear-aware selection);
        # lazily computed -- the common path pays for one top_k only
        def fallback(_):
            elig2 = _cheapest_groups(cfg, w2, a2, take_eff)
            cols2, f2 = _take_lowest(cfg, w2, a2, elig2, True, take_eff)
            return cols2, f2, elig2

        cols, f2, elig = jax.lax.cond(
            f1, lambda _: (cols1, f1, elig1), fallback, None)
        feasible = f1 | f2
        # every eligible group contributes exactly ``take`` elements, so
        # the winning groups are the eligible window itself (ascending)
        win = jnp.nonzero(elig, size=cfg.zone_groups,
                          fill_value=0)[0].astype(jnp.int32)
        eids = (win[:, None] * pg + cols[win]).astype(jnp.int32)
        ranks = jnp.arange(cfg.take, dtype=jnp.int32)[None, :]
        cpos = jnp.arange(cfg.zone_groups, dtype=jnp.int32)[:, None]
        slots = (ranks * cfg.slot_stride + cpos).reshape(-1)
        claimed = slots < n_slots_eff
        elems_row = jnp.zeros(cfg.n_slots, jnp.int32).at[slots].set(
            jnp.where(claimed, eids.reshape(-1), -1))
        lpg = cfg.luns_per_group
        cols_row = (win[:, None] * lpg
                    + jnp.arange(lpg, dtype=jnp.int32)[None, :]
                    ).reshape(-1)[: cfg.parallelism]
        # legacy advances the window even when the allocation then fails
        rr_next = (state.rr_next + cfg.zone_groups) % cfg.n_groups

    if cfg.kind is ElementKind.FIXED:
        flat = elems_row.reshape(-1)
        claimed_flat = jnp.ones_like(flat, dtype=bool)
    else:
        # unclaimed selections scatter into the scratch slot
        flat = jnp.where(claimed, eids.reshape(-1), n)
        claimed_flat = claimed
    ok = limit_ok & feasible
    # deferred physical erase of invalid elements (paper §5 RESET)
    inv = claimed_flat & (state.elem_avail[flat] == AVAIL_INVALID)
    erase_delta = inv.sum().astype(jnp.int32) * cfg.blocks_per_element
    new = state._replace(
        elem_wear=state.elem_wear.at[flat].add(inv.astype(jnp.int32)),
        elem_avail=state.elem_avail.at[flat].set(AVAIL_ALLOCATED),
        elem_pages=state.elem_pages.at[flat].set(0),
        elem_zone=state.elem_zone.at[flat].set(zone),
        zone_state=state.zone_state.at[zone].set(ZONE_OPEN),
        zone_wp=state.zone_wp.at[zone].set(0),
        zone_host_wp=state.zone_host_wp.at[zone].set(0),
        zone_elems=state.zone_elems.at[zone].set(elems_row),
        zone_cols=state.zone_cols.at[zone].set(cols_row),
        n_active=state.n_active + 1,
        block_erases=state.block_erases + erase_delta,
        alloc_calls=state.alloc_calls + 1,
    )
    state = _where_state(ok, new, state)
    # rr advance survives an infeasible attempt (but not a limit refusal,
    # where the legacy device raises before touching the window)
    state = state._replace(
        rr_next=jnp.where(limit_ok, rr_next, state.rr_next))
    return state, ok


def _written_per_slot(cfg: EngineConfig, wp: jax.Array) -> jax.Array:
    return zns.element_pages_jnp(wp, cfg.spec, cfg.parallelism,
                                 cfg.n_segments, cfg.pages_per_block)


def _write(cfg: EngineConfig, dyn: DynConfig, state: DeviceState,
           zone, n_pages, host) -> Tuple[DeviceState, jax.Array]:
    zst0 = state.zone_state[zone]
    state, aok = jax.lax.cond(
        zst0 == ZONE_EMPTY,
        lambda s: _alloc(cfg, dyn, s, zone),
        lambda s: (s, jnp.asarray(True)),
        state)
    wp0 = state.zone_wp[zone]
    wp1 = wp0 + n_pages
    ok = (zst0 != ZONE_FULL) & aok & (wp1 <= dyn.zone_pages)

    written = _written_per_slot(cfg, wp1).astype(jnp.int32)
    elems = state.zone_elems[zone]
    valid = elems >= 0
    idx = jnp.where(valid, elems, cfg.n_elements)
    touched = valid & (written > 0)
    seal = wp1 == dyn.zone_pages
    new = state._replace(
        elem_pages=state.elem_pages.at[idx].set(written),
        elem_avail=state.elem_avail.at[
            jnp.where(touched, elems, cfg.n_elements)].set(AVAIL_VALID),
        zone_wp=state.zone_wp.at[zone].set(wp1),
        zone_host_wp=state.zone_host_wp.at[zone].add(
            jnp.where(host, n_pages, 0)),
        zone_state=state.zone_state.at[zone].set(
            jnp.where(seal, ZONE_FULL, ZONE_OPEN)),
        n_active=state.n_active - seal.astype(jnp.int32),
        host_pages=state.host_pages + jnp.where(host, n_pages, 0),
        dummy_pages=state.dummy_pages + jnp.where(host, 0, n_pages),
    )
    return _where_state(ok, new, state), ok


def _finish(cfg: EngineConfig, state: DeviceState, zone
            ) -> Tuple[DeviceState, jax.Array]:
    zst0 = state.zone_state[zone]
    is_open = zst0 == ZONE_OPEN
    wp = state.zone_wp[zone]
    written = _written_per_slot(cfg, wp).astype(jnp.int32)
    elems = state.zone_elems[zone]
    valid = elems >= 0
    untouched = valid & (written == 0) & is_open
    touched = valid & (written > 0) & is_open
    cap = cfg.pages_per_element
    pad = jnp.sum(jnp.where(touched, cap - written, 0)).astype(jnp.int32)
    n = cfg.n_elements
    u_idx = jnp.where(untouched, elems, n)
    t_idx = jnp.where(touched, elems, n)
    avail = state.elem_avail.at[u_idx].set(AVAIL_FREE)
    avail = avail.at[t_idx].set(AVAIL_VALID)
    pages = state.elem_pages.at[u_idx].set(0)
    pages = pages.at[t_idx].set(cap)
    new = state._replace(
        elem_avail=avail,
        elem_pages=pages,
        elem_zone=state.elem_zone.at[u_idx].set(-1),
        zone_elems=state.zone_elems.at[zone].set(
            jnp.where(untouched, -1, elems)),
        zone_state=state.zone_state.at[zone].set(ZONE_FULL),
        dummy_pages=state.dummy_pages + pad,
        n_active=state.n_active - is_open.astype(jnp.int32),
    )
    # FULL is a no-op; EMPTY just seals (untouched/touched masks are empty)
    return _where_state(zst0 != ZONE_FULL, new, state), jnp.asarray(True)


def _reset(cfg: EngineConfig, state: DeviceState, zone
           ) -> Tuple[DeviceState, jax.Array]:
    zst0 = state.zone_state[zone]
    elems = state.zone_elems[zone]
    valid = elems >= 0
    idx = jnp.where(valid, elems, cfg.n_elements)
    cur = state.elem_avail[idx]
    nxt = jnp.where(cur == AVAIL_VALID, AVAIL_INVALID,
                    jnp.where(cur == AVAIL_ALLOCATED, AVAIL_FREE, cur))
    new = state._replace(
        elem_avail=state.elem_avail.at[idx].set(nxt),
        elem_zone=state.elem_zone.at[idx].set(-1),
        elem_pages=state.elem_pages.at[idx].set(0),
        zone_state=state.zone_state.at[zone].set(ZONE_EMPTY),
        zone_wp=state.zone_wp.at[zone].set(0),
        zone_host_wp=state.zone_host_wp.at[zone].set(0),
        zone_elems=state.zone_elems.at[zone].set(
            jnp.full(cfg.n_slots, -1, jnp.int32)),
        zone_cols=state.zone_cols.at[zone].set(
            jnp.zeros(cfg.parallelism, jnp.int32)),
        n_active=state.n_active - (zst0 == ZONE_OPEN).astype(jnp.int32),
    )
    return new, jnp.asarray(True)


# ----------------------------------------------------------------------- #
# op dispatch + program executor
# ----------------------------------------------------------------------- #
def _apply_op_impl(cfg: EngineConfig, dyn: DynConfig, state: DeviceState,
                   row: jax.Array) -> Tuple[DeviceState, OpTrace]:
    op = row[0]
    zone = jnp.clip(row[1], 0, dyn.n_zones - 1)
    n_pages = row[2]
    host = (row[3] & F_HOST) == F_HOST

    def nop(s):
        return s, jnp.asarray(True)

    def alloc_branch(s):
        zst0 = s.zone_state[zone]
        s2, ok = _alloc(cfg, dyn, s, zone)
        # no-op (and fine) when the zone is already mapped
        return (_where_state(zst0 == ZONE_EMPTY, s2, s),
                jnp.where(zst0 == ZONE_EMPTY, ok, True))

    state2, ok = jax.lax.switch(
        jnp.clip(op, 0, OP_READ),
        [nop,
         alloc_branch,
         lambda s: _write(cfg, dyn, s, zone, n_pages, host),
         lambda s: _finish(cfg, s, zone),
         lambda s: _reset(cfg, s, zone),
         nop],  # OP_READ: reads never change device state
        state)
    trace = OpTrace(
        op=op, zone=zone, ok=ok,
        wp_before=state.zone_wp[zone],
        wp_after=state2.zone_wp[zone],
        host_delta=state2.host_pages - state.host_pages,
        dummy_delta=state2.dummy_pages - state.dummy_pages,
        erase_delta=state2.block_erases - state.block_erases,
        elems=state2.zone_elems[zone],
        cols=state2.zone_cols[zone],
    )
    return state2, trace


@functools.partial(jax.jit, static_argnums=(0,))
def apply_op(cfg: EngineConfig, state: DeviceState, row: jax.Array,
             dyn: Optional[DynConfig] = None
             ) -> Tuple[DeviceState, OpTrace]:
    """One zone command as a pure jitted transition.  ``dyn`` (optional)
    shadows the value-only config fields -- see :class:`DynConfig`."""
    if dyn is None:
        dyn = make_dyn(cfg)
    return _apply_op_impl(cfg, dyn, state, row)


@functools.partial(jax.jit, static_argnums=(0,))
def run_program(cfg: EngineConfig, state: DeviceState, program: jax.Array,
                dyn: Optional[DynConfig] = None
                ) -> Tuple[DeviceState, OpTrace]:
    """Execute an ``(n_ops, >=4)`` int32 program in a single ``lax.scan``.
    Only the first four row columns are interpreted; extra columns (e.g.
    the fleet layer's tenant tag) ride along untouched."""
    if dyn is None:
        dyn = make_dyn(cfg)
    return jax.lax.scan(
        lambda s, r: _apply_op_impl(cfg, dyn, s, r), state, program)


@functools.partial(jax.jit, static_argnums=(0,))
def run_programs(cfg: EngineConfig, state: DeviceState, programs: jax.Array,
                 dyn: Optional[DynConfig] = None
                 ) -> Tuple[DeviceState, OpTrace]:
    """Batch :func:`run_program` over a leading program axis (shared
    initial state) -- a whole parameter sweep in one compiled dispatch.

    ``dyn`` (optional) must hold ``(n_programs,)``-shaped leaves (see
    :func:`stack_dyn`): lane ``k`` runs ``programs[k]`` under
    ``dyn[k]``, which is how a *heterogeneous* fleet (mixed effective
    zone geometries / allocator policies, padded to the largest static
    shape) executes in one dispatch.

    Uses ``lax.map`` rather than ``jax.vmap``: the transitions are
    scatter/gather-heavy and batching them materializes every branch of
    the per-op ``switch`` for every lane, which is several times slower
    on CPU than mapping the already-tight single-device scan."""
    if dyn is None:
        return jax.lax.map(
            lambda p: jax.lax.scan(
                lambda s, r: _apply_op_impl(cfg, make_dyn(cfg), s, r),
                state, p), programs)
    return jax.lax.map(
        lambda pd: jax.lax.scan(
            lambda s, r: _apply_op_impl(cfg, pd[1], s, r), state, pd[0]),
        (programs, dyn))


# ----------------------------------------------------------------------- #
# host-facing wrapper
# ----------------------------------------------------------------------- #
def encode_program(ops, width: int = 4) -> np.ndarray:
    """``[(opcode, zone, n_pages, flags[, ...]), ...]`` -> (n_ops, width)
    int32.  ``width > 4`` leaves room for engine-opaque columns (the
    fleet layer stores a tenant tag in column 4); short rows are
    zero-padded."""
    out = np.zeros((len(ops), width), dtype=np.int32)
    for i, row in enumerate(ops):
        out[i, : len(row)] = row
    return out


class ZoneEngine:
    """Pure functional core of one emulated ZNS device.

    Holds the static :class:`EngineConfig` + :class:`ElementLayout` and
    wraps the module-level jitted transitions; state is always passed
    explicitly (the engine itself is stateless and shareable).
    """

    def __init__(self, flash: FlashGeometry, zone_geom: ZoneGeometry,
                 spec: ElementSpec, *, max_active: int = 14,
                 wear_aware: Optional[bool] = None):
        self.flash = flash
        self.zone_geom = zone_geom
        self.spec = spec
        self.cfg, self.layout = make_config(
            flash, zone_geom, spec, max_active=max_active,
            wear_aware=wear_aware)

    # -- state ---------------------------------------------------------- #
    def init_state(self) -> DeviceState:
        return init_state(self.cfg)

    def dyn(self, **overrides) -> DynConfig:
        """Per-call :class:`DynConfig` (``zone_pages`` / ``max_active`` /
        ``n_zones`` / ``wear_aware`` keywords; others from ``cfg``)."""
        return make_dyn(self.cfg, **overrides)

    def apply(self, state: DeviceState, row,
              dyn: Optional[DynConfig] = None
              ) -> Tuple[DeviceState, OpTrace]:
        return apply_op(self.cfg, state,
                        jnp.asarray(row, jnp.int32), dyn)

    def run(self, state: DeviceState, program: np.ndarray,
            dyn: Optional[DynConfig] = None
            ) -> Tuple[DeviceState, OpTrace]:
        return run_program(self.cfg, state,
                           jnp.asarray(program, jnp.int32), dyn)

    def run_batch(self, state: DeviceState, programs: np.ndarray,
                  dyn: Optional[DynConfig] = None
                  ) -> Tuple[DeviceState, OpTrace]:
        """Batched :meth:`run`; ``dyn`` with ``(n_programs,)`` leaves
        (see :func:`stack_dyn`) makes the batch heterogeneous."""
        return run_programs(self.cfg, state,
                            jnp.asarray(programs, jnp.int32), dyn)

    def warmup(self) -> None:
        """Compile every op branch on a scratch state (one switch jit)."""
        s = self.init_state()
        for op in (OP_ALLOC, OP_WRITE, OP_FINISH, OP_RESET):
            s, _ = self.apply(s, (op, 0, 1, F_HOST))
        jax.block_until_ready(s.elem_wear)

    # -- metrics -------------------------------------------------------- #
    def metrics(self, state: DeviceState) -> dict:
        host = int(state.host_pages)
        dummy = int(state.dummy_pages)
        return {
            "host_pages": float(host),
            "dummy_pages": float(dummy),
            "dlwa": (host + dummy) / host if host else 1.0,
            "block_erases": float(int(state.block_erases)),
            "alloc_calls": float(int(state.alloc_calls)),
            "n_active": float(int(state.n_active)),
        }

    def elem_wear(self, state: DeviceState) -> np.ndarray:
        return np.asarray(state.elem_wear[: self.cfg.n_elements],
                          dtype=np.int64)

    def block_wear(self, state: DeviceState) -> np.ndarray:
        wear = np.zeros(self.flash.n_blocks, dtype=np.int64)
        wear[self.layout.blocks.reshape(-1)] = np.repeat(
            self.elem_wear(state), self.layout.blocks_per_element)
        return wear

    # -- IO stream reconstruction (host-side, post-scan) ---------------- #
    def op_stream(self, op: int, wp_before: int, wp_after: int,
                  dummy_delta: int, elems_after: np.ndarray,
                  cols: np.ndarray):
        """Rebuild the per-page ``(luns, channels)`` stream of one traced
        op, exactly as the legacy device's ``trace=True`` path emits it.
        Returns ``None`` when the op moved no pages."""
        cfg = self.cfg
        cols = np.asarray(cols, dtype=np.int64)
        if op == OP_WRITE and wp_after > wp_before:
            return zns.page_stream(wp_before, wp_after - wp_before,
                                   cfg.parallelism, cfg.pages_per_block,
                                   cols, cfg.n_channels) + ("write",)
        if op == OP_FINISH and dummy_delta > 0:
            written = zns.element_pages(
                wp_before, self.spec, cfg.parallelism, cfg.n_segments,
                cfg.pages_per_block)
            padded = np.nonzero((np.asarray(elems_after) >= 0)
                                & (written > 0)
                                & (written < cfg.pages_per_element))[0]
            return zns.pad_stream(
                wp_before, cfg.zone_pages, self.spec, cfg.parallelism,
                cfg.pages_per_block, cols, padded.astype(np.int64),
                cfg.n_channels) + ("write",)
        return None
