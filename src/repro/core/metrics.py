"""Evaluation metrics (paper §6.1 "Evaluation Metrics")."""

from __future__ import annotations

import dataclasses
from typing import Dict, List

import numpy as np

from repro.core.device import ZNSDevice


def dlwa(host_pages: int, device_pages: int) -> float:
    """Device-level write amplification: (W_h + W_d) / W_h."""
    if host_pages == 0:
        return 1.0
    return (host_pages + device_pages) / host_pages


@dataclasses.dataclass
class SATracker:
    """Space amplification (paper §6.1/Fig. 1): the ratio of data the
    system must keep on device (live + invalidated-but-unreclaimed) to the
    live host data, sampled per timestamp and averaged:

        SA(t) = (W_live(t) + W_i(t)) / W_live(t)

    W_i grows when files are deleted inside zones that still hold live
    data (lifetime mixing) and shrinks when a fully-invalid zone RESETs.
    """

    live_bytes: float = 0.0
    invalid_bytes: float = 0.0
    _samples: List[float] = dataclasses.field(default_factory=list)

    def on_host_write(self, nbytes: float) -> None:
        self.live_bytes += nbytes

    def on_invalidate(self, nbytes: float) -> None:
        self.live_bytes = max(0.0, self.live_bytes - nbytes)
        self.invalid_bytes += nbytes

    def on_reclaim(self, nbytes: float) -> None:
        self.invalid_bytes = max(0.0, self.invalid_bytes - nbytes)

    def sample(self) -> None:
        if self.live_bytes > 0:
            self._samples.append(
                (self.live_bytes + self.invalid_bytes) / self.live_bytes)

    @property
    def sa(self) -> float:
        return float(np.mean(self._samples)) if self._samples else 1.0


def wear_report(dev: ZNSDevice) -> Dict[str, float]:
    """Total + distributional wear (paper Fig. 7c)."""
    w = dev.block_wear()
    return {
        "total_block_erases": float(dev.block_erases),
        "pending_block_erases": float(dev.pending_erases()),
        "total_incl_pending": float(dev.block_erases + dev.pending_erases()),
        "mean_wear": float(w.mean()),
        "max_wear": float(w.max()),
        "std_wear": float(w.std()),
        "cv_wear": float(w.std() / w.mean()) if w.mean() > 0 else 0.0,
    }


def interference_factor(baseline_throughput: float,
                        contended_throughput: float) -> float:
    """Ratio of baseline host throughput to throughput under concurrent
    FINISH (paper §6.1); >1 means the device slows the host down."""
    if contended_throughput <= 0:
        return float("inf")
    return baseline_throughput / contended_throughput
