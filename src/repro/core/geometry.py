"""Flash + zone geometry for the augmented ZNS design space (paper §2-§4).

The paper abstracts the SSD as L parallel units (LUNs), each holding
``blocks_per_lun`` erase blocks of ``pages_per_block`` pages.  A *zone* is
built from *segments*; a segment spans ``zone_parallelism`` (P) LUNs with
one erase block per LUN, so a zone of ``n_segments`` segments holds
``n_segments * P`` erase blocks.  Writes are striped page-round-robin
across the P LUN columns of the current segment (paper Fig. 3b).

Two concrete devices from the paper (§6.1):

* ``zn540()``   — the ConfZNS++ model of a WD ZN540 (4 LUNs, 16 KiB pages,
  768-page blocks, 1 GiB zones = 22 superblocks, 48 zones, 14 active).
* ``custom16()`` — the paper's custom SSD (8 channels x 2 ways = 16 LUNs,
  4 KiB pages, 2048-page blocks -> 8 MiB blocks, 128 superblocks).
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

KIB = 1024
MIB = 1024 * 1024


@dataclasses.dataclass(frozen=True)
class FlashGeometry:
    """Physical geometry of the emulated flash device."""

    n_channels: int
    ways_per_channel: int
    blocks_per_lun: int
    pages_per_block: int
    page_bytes: int
    # timing constants (seconds) -- FEMU-style per-op latencies
    t_prog: float = 500e-6
    t_read: float = 50e-6
    t_erase: float = 5e-3
    t_xfer: float = 25e-6

    @property
    def n_luns(self) -> int:
        return self.n_channels * self.ways_per_channel

    @property
    def n_blocks(self) -> int:
        return self.n_luns * self.blocks_per_lun

    @property
    def block_bytes(self) -> int:
        return self.pages_per_block * self.page_bytes

    @property
    def lun_bytes(self) -> int:
        return self.blocks_per_lun * self.block_bytes

    @property
    def device_bytes(self) -> int:
        return self.n_luns * self.lun_bytes

    def lun_of_block(self, block: int) -> int:
        """Blocks are numbered LUN-major: block = lun * blocks_per_lun + off."""
        return block // self.blocks_per_lun

    def channel_of_lun(self, lun: int) -> int:
        return lun % self.n_channels


@dataclasses.dataclass(frozen=True)
class ZoneGeometry:
    """Logical zone shape: P LUNs of parallelism x n_segments segments."""

    parallelism: int  # P: number of LUN columns a segment spans
    n_segments: int   # number of segments stacked in a zone

    @property
    def blocks_per_zone(self) -> int:
        return self.parallelism * self.n_segments

    def zone_bytes(self, flash: FlashGeometry) -> int:
        return self.blocks_per_zone * flash.block_bytes

    def zone_pages(self, flash: FlashGeometry) -> int:
        return self.blocks_per_zone * flash.pages_per_block

    def segment_pages(self, flash: FlashGeometry) -> int:
        return self.parallelism * flash.pages_per_block

    def max_zones(self, flash: FlashGeometry) -> int:
        """Upper bound on simultaneously-mapped zones for this geometry."""
        return flash.n_blocks // self.blocks_per_zone

    def describe(self, flash: FlashGeometry) -> str:
        return (
            f"P{self.parallelism}, S{self.zone_bytes(flash) // MIB}"
        )


def zn540() -> Tuple[FlashGeometry, ZoneGeometry]:
    """ConfZNS++ model of the WD ZN540 (paper §6.1, 'Baseline ZNS SSD').

    4 channels, 16 KiB pages, 768-page blocks (12 MiB).  Zone capacity
    ~1 GiB built from 22 superblocks of 4 blocks each -> 88 blocks/zone.
    48 zones total, 14 open/active.  Latencies 700us W / 60us R / 3.5ms E.
    """
    flash = FlashGeometry(
        n_channels=4,
        ways_per_channel=1,
        blocks_per_lun=48 * 22,  # 48 zones x 22 superblocks x 1 block per LUN
        pages_per_block=768,
        page_bytes=16 * KIB,
        t_prog=700e-6,
        t_read=60e-6,
        t_erase=3.5e-3,
        t_xfer=25e-6,
    )
    zone = ZoneGeometry(parallelism=4, n_segments=22)
    return flash, zone


def custom16() -> FlashGeometry:
    """The paper's custom SSD (§6.1): 8 ch x 2 ways = 16 LUNs, 4 KiB pages,
    2048-page (8 MiB) blocks, 128 blocks per LUN (128 superblocks),
    500us W / 50us R / 25us xfer / 5ms E."""
    return FlashGeometry(
        n_channels=8,
        ways_per_channel=2,
        blocks_per_lun=128,
        pages_per_block=2048,
        page_bytes=4 * KIB,
        t_prog=500e-6,
        t_read=50e-6,
        t_erase=5e-3,
        t_xfer=25e-6,
    )


#: The six zone-geometry configurations of paper Fig. 6 (for custom16()).
#: (parallelism P, n_segments) -> named "P{P}, S{MiB}".
PAPER_GEOMETRIES: Tuple[ZoneGeometry, ...] = (
    ZoneGeometry(parallelism=16, n_segments=1),   # P16, S128
    ZoneGeometry(parallelism=16, n_segments=2),   # P16, S256
    ZoneGeometry(parallelism=8, n_segments=1),    # P8,  S64
    ZoneGeometry(parallelism=8, n_segments=2),    # P8,  S128
    ZoneGeometry(parallelism=4, n_segments=1),    # P4,  S32
    ZoneGeometry(parallelism=4, n_segments=2),    # P4,  S64
)
