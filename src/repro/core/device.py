"""The emulated ZNS device: a thin stateful shim over ``repro.core.engine``.

State machine per paper §5 ("Integration with SSD Emulator") -- see
:mod:`repro.core.engine` for the transitions.  Since PR 2 the device's
*data plane* (wear/avail/pages matrices, zone mapping table, counters)
lives in a :class:`repro.core.engine.DeviceState` pytree and every
command dispatches one jit-compiled pure transition; this class only
keeps a host-side control-plane mirror (zone states/write pointers,
Python-int counters) so it can raise the legacy ``RuntimeError``s
eagerly, serve :class:`ZoneInfo` views to hosts like ``ZoneFS``, and
build ``trace=True`` IO streams without device round-trips.

The shim is API- and bit-compatible with the original implementation
(now :class:`repro.core.device_legacy.LegacyZNSDevice`); the
differential property tests replay random op sequences through both.

Availability codes: 0 free, 1 allocated-empty, 2 valid, 3 invalid.
"""

from __future__ import annotations

import dataclasses
import enum
import time
from typing import Dict, List, Optional

import numpy as np

from repro.core import engine as zengine
from repro.core import zns
from repro.core.alloc_exact import AVAIL_INVALID
from repro.core.elements import ElementLayout, ElementSpec
from repro.core.geometry import FlashGeometry, ZoneGeometry


class ZoneState(enum.Enum):
    EMPTY = 0
    OPEN = 1
    FULL = 2


@dataclasses.dataclass
class ZoneInfo:
    state: ZoneState = ZoneState.EMPTY
    wp: int = 0                                  # pages written (host+dummy)
    host_wp: int = 0                             # pages written by host
    elements: Optional[np.ndarray] = None        # slot -> element id (-1 = released)
    column_luns: Optional[np.ndarray] = None     # zone column -> LUN id


@dataclasses.dataclass
class IOTrace:
    """Per-page (op, lun, channel) streams for the timing model."""
    luns: np.ndarray
    channels: np.ndarray
    op: str  # 'write' | 'read' | 'erase'


class ZNSDevice:
    """One emulated ZNS SSD with a pluggable zone-allocation granularity.

    A stateful facade: commands are validated against the host-side
    mirror, executed as pure engine transitions on ``self.state``, and
    the mirror is refreshed from the returned trace slice.
    """

    def __init__(self,
                 flash: FlashGeometry,
                 zone_geom: ZoneGeometry,
                 spec: ElementSpec,
                 *,
                 max_active: int = 14,
                 alloc_impl: str = "xla",
                 wear_aware: Optional[bool] = None):
        self.flash = flash
        self.zone_geom = zone_geom
        self.spec = spec
        self.max_active = max_active
        self.alloc_impl = alloc_impl  # kept for API compat; engine uses XLA

        self.engine = zengine.ZoneEngine(
            flash, zone_geom, spec, max_active=max_active,
            wear_aware=wear_aware)
        cfg = self.engine.cfg
        self.wear_aware = cfg.wear_aware
        self.layout: ElementLayout = self.engine.layout
        self.elems_per_zone = cfg.take * cfg.zone_groups
        self.zone_groups = cfg.zone_groups
        self.take_per_group = cfg.take
        self.per_group = cfg.per_group
        self.zone_pages = cfg.zone_pages
        self.n_zones = cfg.n_zones

        self.state: zengine.DeviceState = self.engine.init_state()
        self.zones: Dict[int, ZoneInfo] = {
            z: ZoneInfo() for z in range(self.n_zones)}

        # counters (host-side mirrors of the pytree scalars, as Python
        # ints so long workloads can't overflow int32)
        self.host_pages = 0
        self.dummy_pages = 0
        self.block_erases = 0
        self.alloc_calls = 0
        self.alloc_seconds = 0.0
        self.alloc_latencies_us: List[float] = []

    # ------------------------------------------------------------------ #
    # metrics
    # ------------------------------------------------------------------ #
    @property
    def dlwa(self) -> float:
        if self.host_pages == 0:
            return 1.0
        return (self.host_pages + self.dummy_pages) / self.host_pages

    @property
    def n_active(self) -> int:
        return sum(1 for z in self.zones.values()
                   if z.state is ZoneState.OPEN)

    # element-state views (numpy copies of the pytree data plane)
    @property
    def elem_wear(self) -> np.ndarray:
        return np.asarray(
            self.state.elem_wear[: self.layout.n_elements], dtype=np.int64)

    @property
    def elem_avail(self) -> np.ndarray:
        return np.asarray(
            self.state.elem_avail[: self.layout.n_elements], dtype=np.int32)

    @property
    def elem_pages(self) -> np.ndarray:
        return np.asarray(
            self.state.elem_pages[: self.layout.n_elements], dtype=np.int64)

    @property
    def elem_zone(self) -> np.ndarray:
        return np.asarray(
            self.state.elem_zone[: self.layout.n_elements], dtype=np.int32)

    def block_wear(self) -> np.ndarray:
        """Per erase-block wear (all blocks of an element share wear)."""
        return self.engine.block_wear(self.state)

    def pending_erases(self) -> int:
        """Block erases implied by a=3 elements not yet re-allocated."""
        inv = self.elem_avail == AVAIL_INVALID
        return int(inv.sum()) * self.layout.blocks_per_element

    # ------------------------------------------------------------------ #
    # engine dispatch + mirror upkeep
    # ------------------------------------------------------------------ #
    def _dispatch(self, op: int, zone_id: int, n_pages: int = 0,
                  host: bool = True) -> zengine.OpTrace:
        self.state, tr = self.engine.apply(
            self.state,
            (op, zone_id, n_pages, zengine.F_HOST if host else 0))
        return tr

    def _allocate_zone(self, zone_id: int) -> None:
        if self.n_active >= self.max_active:
            raise RuntimeError(
                f"open/active zone limit ({self.max_active}) reached")
        t0 = time.perf_counter()
        tr = self._dispatch(zengine.OP_ALLOC, zone_id)
        ok = bool(tr.ok)  # blocks until the transition is done
        dt = time.perf_counter() - t0
        if not ok:
            raise RuntimeError("no free storage elements for zone "
                               f"{zone_id} ({self.spec.name})")
        self.alloc_calls += 1
        self.alloc_seconds += dt
        self.alloc_latencies_us.append(dt * 1e6)
        self.block_erases += int(tr.erase_delta)
        info = self.zones[zone_id]
        info.elements = np.asarray(tr.elems, dtype=np.int64)
        info.column_luns = np.asarray(tr.cols, dtype=np.int64)
        info.state = ZoneState.OPEN
        info.wp = 0
        info.host_wp = 0

    def warmup_alloc(self) -> None:
        """Compile every engine transition on a scratch state so timed
        allocation samples exclude jit compilation (paper Table 4
        methodology)."""
        self.engine.warmup()

    # ------------------------------------------------------------------ #
    # ZNS commands
    # ------------------------------------------------------------------ #
    def zone_write(self, zone_id: int, n_pages: int,
                   *, host: bool = True, trace: bool = False
                   ) -> Optional[IOTrace]:
        info = self.zones[zone_id]
        if info.state is ZoneState.FULL:
            raise RuntimeError(f"write to FULL zone {zone_id}")
        if info.state is ZoneState.EMPTY:
            self._allocate_zone(zone_id)
        if info.wp + n_pages > self.zone_pages:
            raise RuntimeError(
                f"zone {zone_id} overflow: wp={info.wp} + {n_pages} "
                f"> {self.zone_pages}")
        self._dispatch(zengine.OP_WRITE, zone_id, n_pages, host=host)
        start = info.wp
        info.wp += n_pages
        if host:
            info.host_wp += n_pages
            self.host_pages += n_pages
        else:
            self.dummy_pages += n_pages
        if info.wp == self.zone_pages:
            info.state = ZoneState.FULL
        if trace:
            luns, chans = zns.page_stream(
                start, n_pages, self.zone_geom.parallelism,
                self.flash.pages_per_block, info.column_luns,
                self.flash.n_channels)
            return IOTrace(luns, chans, "write")
        return None

    def zone_read(self, zone_id: int, pages: np.ndarray) -> IOTrace:
        info = self.zones[zone_id]
        if info.column_luns is None:
            raise RuntimeError(f"read from unmapped zone {zone_id}")
        luns, chans = zns.read_stream(
            pages, self.zone_geom.parallelism, self.flash.pages_per_block,
            info.column_luns, self.flash.n_channels)
        return IOTrace(luns, chans, "read")

    def zone_finish(self, zone_id: int, *, trace: bool = False
                    ) -> Optional[IOTrace]:
        """FINISH: pad partially-written elements, release untouched ones.

        Returns the dummy-write IOTrace when ``trace`` (for interference
        simulation).
        """
        info = self.zones[zone_id]
        if info.state is ZoneState.FULL:
            return None
        if info.state is ZoneState.EMPTY:
            self._dispatch(zengine.OP_FINISH, zone_id)
            info.state = ZoneState.FULL  # finishing an empty zone is a no-op
            return None
        wp_at_finish = info.wp
        tr = self._dispatch(zengine.OP_FINISH, zone_id)
        self.dummy_pages += int(tr.dummy_delta)
        info.elements = np.asarray(tr.elems, dtype=np.int64)
        info.state = ZoneState.FULL
        if trace:
            written = zns.element_pages(
                wp_at_finish, self.spec, self.zone_geom.parallelism,
                self.zone_geom.n_segments, self.flash.pages_per_block)
            padded_slots = np.nonzero(
                (info.elements >= 0) & (written > 0)
                & (written < self.layout.pages_per_element))[0]
            luns, chans = zns.pad_stream(
                wp_at_finish, self.zone_pages, self.spec,
                self.zone_geom.parallelism, self.flash.pages_per_block,
                info.column_luns, padded_slots.astype(np.int64),
                self.flash.n_channels)
            return IOTrace(luns, chans, "write")
        return None

    def zone_reset(self, zone_id: int) -> None:
        """Partial + asynchronous RESET (paper §5): invalidate metadata,
        defer physical erase to re-allocation."""
        self._dispatch(zengine.OP_RESET, zone_id)
        self.zones[zone_id] = ZoneInfo()

    def median_alloc_latency_us(self) -> float:
        if not self.alloc_latencies_us:
            return 0.0
        return float(np.median(self.alloc_latencies_us))
