"""Exact solver for the SilentZNS zone-allocation integer program (paper §5).

The ILP (Eqs. 1-6):

    minimize   sum_n c_n * w_n
    subject to c_n = 0 unless a_n in {0, 3}              (availability)
               sum_n c_n = Z                             (zone size)
               s_l <= sum_{n in LUN l} c_n <= K * s_l    (coupling)
               sum_l s_l >= L_min                        (parallelism)
               s_l = 0 for l not in L_elig               (round-robin)

Key structure: once the *count* j_l of elements taken from each group l is
fixed, the optimum takes the j_l lowest-wear available elements of that
group.  So the ILP reduces to choosing counts {j_l}, which we solve with an
exact dynamic program over groups:

    dp[g][z][a] = min cost using the first g groups, z elements selected,
                  a active groups.

This is O(G * Z^2 * G) worst case -- tiny for device-scale instances and
used as the *oracle* in tests for both the vectorized JAX allocator and the
Pallas ``zns_alloc`` kernel.
"""

from __future__ import annotations

import dataclasses
from typing import List, Sequence

import numpy as np

INF = float("inf")

#: availability codes (paper §5): 0 free, 1 allocated-empty, 2 valid data,
#: 3 invalid data (free for re-allocation after erase).
AVAIL_FREE = 0
AVAIL_ALLOCATED = 1
AVAIL_VALID = 2
AVAIL_INVALID = 3

ALLOCATABLE = (AVAIL_FREE, AVAIL_INVALID)


@dataclasses.dataclass
class ExactSolution:
    cost: float
    selected: np.ndarray        # element ids, sorted
    counts_per_group: np.ndarray
    feasible: bool


def solve(wear: np.ndarray,
          avail: np.ndarray,
          group: np.ndarray,
          *,
          z: int,
          k_max: int,
          l_min: int,
          eligible_groups: Sequence[int]) -> ExactSolution:
    """Solve the allocation ILP exactly. Arrays are 1-D over elements."""
    wear = np.asarray(wear, dtype=np.float64)
    avail = np.asarray(avail)
    group = np.asarray(group)
    n_groups = int(group.max()) + 1 if group.size else 0
    eligible = sorted(set(int(g) for g in eligible_groups))

    # Per-eligible-group sorted available wears + element ids.
    per_group_sorted: List[np.ndarray] = []
    per_group_ids: List[np.ndarray] = []
    for g in eligible:
        ok = (group == g) & np.isin(avail, ALLOCATABLE)
        ids = np.nonzero(ok)[0]
        order = np.argsort(wear[ids], kind="stable")
        per_group_sorted.append(wear[ids][order])
        per_group_ids.append(ids[order])

    G = len(eligible)
    # prefix[g][j] = cost of taking the j cheapest from group g
    prefix = []
    for ws in per_group_sorted:
        j_max = min(k_max, len(ws))
        p = np.zeros(j_max + 1)
        p[1:] = np.cumsum(ws[:j_max])
        prefix.append(p)

    # dp[z][a] over groups
    dp = np.full((z + 1, G + 1), INF)
    dp[0][0] = 0.0
    choice = np.full((G, z + 1, G + 1), -1, dtype=np.int64)
    for gi in range(G):
        ndp = np.full_like(dp, INF)
        jmax = len(prefix[gi]) - 1
        for zz in range(z + 1):
            for aa in range(G + 1):
                if dp[zz][aa] == INF:
                    continue
                for j in range(0, min(jmax, z - zz) + 1):
                    na = aa + (1 if j > 0 else 0)
                    c = dp[zz][aa] + prefix[gi][j]
                    if c < ndp[zz + j][na]:
                        ndp[zz + j][na] = c
                        choice[gi][zz + j][na] = j
        dp = ndp

    best_a, best_cost = -1, INF
    for aa in range(l_min, G + 1):
        if dp[z][aa] < best_cost:
            best_cost = dp[z][aa]
            best_a = aa
    if best_a < 0:
        return ExactSolution(INF, np.empty(0, np.int64),
                             np.zeros(G, np.int64), False)

    # backtrack
    counts = np.zeros(G, dtype=np.int64)
    zz, aa = z, best_a
    for gi in range(G - 1, -1, -1):
        j = int(choice[gi][zz][aa])
        counts[gi] = j
        zz -= j
        aa -= 1 if j > 0 else 0
    selected = np.concatenate(
        [per_group_ids[gi][: counts[gi]] for gi in range(G)]
        or [np.empty(0, np.int64)])
    return ExactSolution(float(best_cost), np.sort(selected),
                         counts, True)


def solve_even(wear: np.ndarray, avail: np.ndarray, group: np.ndarray, *,
               take_per_group: int,
               eligible_groups: Sequence[int]) -> ExactSolution:
    """The balanced special case used by every paper configuration: take
    exactly ``take_per_group`` lowest-wear elements from each eligible
    group (equivalent to the ILP with K = take = Z / |L_elig| and
    L_min = |L_elig|)."""
    wear = np.asarray(wear, dtype=np.float64)
    sel: List[np.ndarray] = []
    cost = 0.0
    feasible = True
    counts = []
    for g in eligible_groups:
        ok = (group == g) & np.isin(avail, ALLOCATABLE)
        ids = np.nonzero(ok)[0]
        if len(ids) < take_per_group:
            feasible = False
            counts.append(len(ids))
            continue
        order = np.argsort(wear[ids], kind="stable")[:take_per_group]
        sel.append(ids[order])
        cost += float(wear[ids][order].sum())
        counts.append(take_per_group)
    selected = (np.sort(np.concatenate(sel)) if sel
                else np.empty(0, np.int64))
    return ExactSolution(cost if feasible else INF, selected,
                         np.asarray(counts), feasible)
