"""The paper's headline figures as batched engine dispatches.

The paper's summary numbers -- ~92% lower DLWA at 10% occupancy, up to
12% less wear, up to 3.7x faster workload execution -- all compare
SilentZNS (a zone = an arbitrary block collection committed on the fly)
against the traditional static logical-to-physical mapping (a zone's
whole block set committed at allocation).  This module reproduces each
figure as ONE batched :func:`repro.core.engine.run_programs` dispatch
over paired lanes of a *union* engine:

* the **traditional** lane runs ``alloc_policy="traditional"`` on the
  whole-zone-commitment element spec (``hchunk(n_segments)``: each
  element is one LUN's full zone span, so ALLOC pins -- and FINISH must
  pad -- the entire zone);
* the **silent** lane runs ``alloc_policy="silent"`` on ``BLOCK``
  granularity: ALLOC commits only the erase blocks the write at hand
  needs (under the wear bound and the one-block-per-LUN-group
  parallelism floor) and grows the zone on demand.

Figures (each one dispatch, shapes stable across repeats):

* :func:`dlwa_figure` -- DLWA vs occupancy (paper Fig. 1a/4a);
* :func:`wear_figure` -- total block erases under RESET churn (the
  superfluous-erase traffic of pinned-but-unwritten blocks);
* :func:`exec_figure` -- workload execution time via the op-granular
  fleet timing model (FINISH padding is real program traffic).

:func:`paper_report` assembles all three plus a recompile-stability
probe into the ``BENCH_paper.json`` artifact gated by
``tools/bench.py``; ``benchmarks/paper_headline.py`` is the CLI.
The per-occupancy DLWA points are differentially tested against the
per-op ``LegacyZNSDevice`` oracle at small geometry in
``tests/test_engine_diff.py``.
"""

from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from repro.core import engine as zengine
from repro.core import timing, workloads
from repro.core.elements import BLOCK, ElementSpec, hchunk
from repro.core.engine import ZoneEngine, stack_dyn
from repro.core.geometry import FlashGeometry, ZoneGeometry, zn540

#: occupancy sweep of the DLWA figure (10% first: the gated point)
DEFAULT_OCCUPANCIES: Tuple[float, ...] = (0.1, 0.2, 0.3, 0.5, 0.7, 0.9)


def traditional_spec(zone_geom: ZoneGeometry) -> ElementSpec:
    """The traditional mapping's element spec: one element = one LUN's
    whole zone span (``hchunk(n_segments)``), so allocation commits --
    and FINISH pads -- the full zone, exactly like a static
    logical-to-physical zone table.  (FIXED models the same commitment
    but cannot join a spec union; hchunk at the full segment count is
    its gridded equivalent.)"""
    return hchunk(zone_geom.n_segments)


def build_headline_engine(flash: Optional[FlashGeometry] = None,
                          zone_geom: Optional[ZoneGeometry] = None, *,
                          max_active: int = 14) -> ZoneEngine:
    """The union engine both policies share (defaults to the zn540
    model): one dispatch can then pair traditional whole-zone lanes
    with silent BLOCK lanes."""
    if (flash is None) != (zone_geom is None):
        raise ValueError("flash and zone_geom must be given together")
    if flash is None:
        flash, zone_geom = zn540()
    return ZoneEngine(flash, zone_geom,
                      (traditional_spec(zone_geom), BLOCK),
                      max_active=max_active)


def _policy_dyns(eng: ZoneEngine, n_pairs: int,
                 wear_bound: Optional[int] = None):
    """Stacked per-lane DynConfigs for ``n_pairs`` (traditional,
    silent) lane pairs -- lane ``2k`` traditional, lane ``2k + 1``
    silent."""
    trad = eng.dyn(spec=traditional_spec(eng.zone_geom))
    silent = eng.dyn(spec=BLOCK, alloc_policy="silent",
                     wear_bound=wear_bound)
    return stack_dyn([trad, silent] * n_pairs)


def _assert_all_ok(trace, what: str) -> None:
    ok = np.asarray(trace.ok)
    if not ok.all():
        lanes, ops = np.nonzero(~ok)
        raise RuntimeError(
            f"{what}: {int((~ok).sum())} op(s) reported ok=0 "
            f"(first at lane {int(lanes[0])}, op {int(ops[0])})")


def _lane_metric(states, field: str) -> np.ndarray:
    return np.asarray(getattr(states, field), dtype=np.int64)


def dlwa_figure(eng: ZoneEngine,
                occupancies: Sequence[float] = DEFAULT_OCCUPANCIES, *,
                n_zones: int = 4,
                wear_bound: Optional[int] = None) -> Dict:
    """DLWA vs occupancy, both policies, ONE dispatch.

    Each occupancy point is a fill-to-occupancy + FINISH program
    (:func:`repro.core.workloads.dlwa_program`) executed by a
    traditional lane and a silent lane; the reduction at each point is
    ``1 - silent / traditional``.  The paper's headline gate reads the
    10%-occupancy point."""
    occupancies = [float(o) for o in occupancies]
    programs = np.stack([
        p for o in occupancies
        for p in (workloads.dlwa_program(eng, occupancy=o,
                                         n_zones=n_zones),) * 2])
    dyn = _policy_dyns(eng, len(occupancies), wear_bound)
    states, trace = eng.run_batch(eng.init_state(), programs, dyn)
    _assert_all_ok(trace, "dlwa_figure")
    host = _lane_metric(states, "host_pages")
    dummy = _lane_metric(states, "dummy_pages")
    dlwa = (host + dummy) / np.maximum(host, 1)
    trad, silent = dlwa[0::2], dlwa[1::2]
    return {
        "occupancies": occupancies,
        "n_zones": float(n_zones),
        "traditional_dlwa": [float(x) for x in trad],
        "silent_dlwa": [float(x) for x in silent],
        "dlwa_reduction": [float(1.0 - s / t)
                           for s, t in zip(silent, trad)],
    }


def dlwa_reduction_at(figure: Dict, occupancy: float = 0.1) -> float:
    """The DLWA reduction at the sweep point nearest ``occupancy``
    (the 10% point is the gated headline number)."""
    occs = figure["occupancies"]
    i = int(np.argmin(np.abs(np.asarray(occs) - occupancy)))
    return float(figure["dlwa_reduction"][i])


def _churn_program(eng: ZoneEngine, *, occupancy: float, n_zones: int,
                   cycles: int) -> np.ndarray:
    """``cycles`` rounds of fill-to-occupancy + FINISH + RESET over
    ``n_zones`` zones: re-allocation after RESET is what converts
    pinned-but-dirty blocks into deferred erases (paper §5), so this is
    the traffic where the policies' wear diverges."""
    zp = int(eng.cfg.zone_pages)
    host = min(zp, max(1, int(round(zp * occupancy))))
    rows = []
    for _ in range(cycles):
        for z in range(n_zones):
            rows += [(zengine.OP_WRITE, z, host, zengine.F_HOST),
                     (zengine.OP_FINISH, z, 0, 0),
                     (zengine.OP_RESET, z, 0, 0)]
    return zengine.encode_program(rows)


def wear_figure(eng: ZoneEngine, *, occupancy: float = 0.3,
                n_zones: int = 8, cycles: int = 8,
                wear_bound: Optional[int] = None) -> Dict:
    """Total block erases under RESET churn, both policies, ONE
    dispatch.  The traditional lane re-commits (and therefore
    re-erases) every block of the zone each cycle; the silent lane only
    ever touches the blocks the occupancy needs."""
    program = _churn_program(eng, occupancy=occupancy, n_zones=n_zones,
                             cycles=cycles)
    programs = np.stack([program, program])
    dyn = _policy_dyns(eng, 1, wear_bound)
    states, trace = eng.run_batch(eng.init_state(), programs, dyn)
    _assert_all_ok(trace, "wear_figure")
    erases = _lane_metric(states, "block_erases")
    trad, silent = int(erases[0]), int(erases[1])
    return {
        "occupancy": float(occupancy),
        "n_zones": float(n_zones),
        "cycles": float(cycles),
        "traditional_erases": float(trad),
        "silent_erases": float(silent),
        "wear_reduction": float(1.0 - silent / trad) if trad else 0.0,
    }


def exec_figure(eng: ZoneEngine, *, occupancy: float = 0.3,
                n_zones: int = 8, cycles: int = 4,
                wear_bound: Optional[int] = None) -> Dict:
    """Workload execution time, both policies, ONE engine dispatch +
    ONE batched timing dispatch.

    Both lanes execute identical host traffic; the traditional lane's
    FINISH ops must additionally program the whole-zone dummy padding,
    which the op-granular fleet timing model
    (:func:`repro.core.timing.simulate_fleet_ops`) prices like any
    other page traffic.  Speedup = traditional makespan / silent
    makespan."""
    program = _churn_program(eng, occupancy=occupancy, n_zones=n_zones,
                             cycles=cycles)
    programs = np.stack([program, program])
    dyn = _policy_dyns(eng, 1, wear_bound)
    states, trace = eng.run_batch(eng.init_state(), programs, dyn)
    _assert_all_ok(trace, "exec_figure")
    # pages an op physically programmed: host writes plus FINISH padding
    pages = (np.asarray(trace.host_delta)
             + np.asarray(trace.dummy_delta)).astype(np.int32)
    cols = np.asarray(trace.cols, dtype=np.int32)
    tenants = np.zeros(pages.shape, dtype=np.int32)
    t_page = float(eng.flash.t_prog + eng.flash.t_xfer)
    _, _, makespans = timing.simulate_fleet_ops(
        cols, pages, tenants, t_page, eng.flash.n_luns, 1)
    makespans = np.asarray(makespans, dtype=np.float64)
    trad, silent = float(makespans[0]), float(makespans[1])
    return {
        "occupancy": float(occupancy),
        "n_zones": float(n_zones),
        "cycles": float(cycles),
        "host_pages": float(int(states.host_pages[0])),
        "traditional_s": trad,
        "silent_s": silent,
        "speedup": trad / silent if silent else 0.0,
    }


def paper_report(flash: Optional[FlashGeometry] = None,
                 zone_geom: Optional[ZoneGeometry] = None, *,
                 occupancies: Sequence[float] = DEFAULT_OCCUPANCIES,
                 dlwa_zones: int = 4, wear_zones: int = 8,
                 wear_cycles: int = 8, exec_cycles: int = 4,
                 wear_bound: Optional[int] = None,
                 max_active: int = 14) -> Dict:
    """All three headline figures plus a recompile-stability probe.

    Every figure is dispatched twice; the second pass must not add jit
    cache entries (``recompiles.delta_total == 0``), which is the
    shape-stability property the ``BENCH_paper.json`` gate asserts."""
    from repro.obs.profile import RecompileCounter

    eng = build_headline_engine(flash, zone_geom, max_active=max_active)
    rec = RecompileCounter(run_programs=zengine.run_programs,
                           simulate_fleet_ops=timing.simulate_fleet_ops)

    def figures():
        return {
            "dlwa": dlwa_figure(eng, occupancies, n_zones=dlwa_zones,
                                wear_bound=wear_bound),
            "wear": wear_figure(eng, n_zones=wear_zones,
                                cycles=wear_cycles,
                                wear_bound=wear_bound),
            "exec": exec_figure(eng, n_zones=wear_zones,
                                cycles=exec_cycles,
                                wear_bound=wear_bound),
        }

    first = figures()         # compiles the three dispatch shapes
    before = rec.counts()
    out = figures()           # must hit the caches
    delta = rec.delta(before)
    for name in first:
        assert first[name] == out[name], (
            f"paper figure {name!r} is not deterministic across "
            f"repeated dispatches")
    out["dlwa"]["reduction_at_10pct"] = dlwa_reduction_at(out["dlwa"])
    out["recompiles"] = {
        "entries": {k: float(v) for k, v in rec.counts().items()},
        "delta": {k: float(v) for k, v in delta.items()},
        "delta_total": float(sum(delta.values())),
    }
    return out
