"""SilentZNS core: the paper's contribution as a composable JAX library."""

from repro.core.geometry import (FlashGeometry, ZoneGeometry, zn540,
                                 custom16, PAPER_GEOMETRIES, MIB, KIB)
from repro.core.elements import (ElementKind, ElementSpec, ElementLayout,
                                 BLOCK, SUPERBLOCK, FIXED, hchunk, vchunk,
                                 PAPER_ELEMENTS, build_layout,
                                 elements_per_zone, groups_per_zone,
                                 is_applicable)
from repro.core.device import ZNSDevice, ZoneState, ZoneInfo, IOTrace
from repro.core.engine import (DeviceState, DynConfig, EngineConfig,
                               OpTrace, SpecValues, ZoneEngine,
                               encode_program, make_dyn,
                               make_union_config, stack_dyn)
from repro.core.backend import ZoneBackend, check_backend
from repro.core.allocator import (select_lowest_wear, allocate, RoundRobin,
                                  eligible_mask)
from repro.core import alloc_exact, engine, metrics, timing, workloads, zns

__all__ = [
    "FlashGeometry", "ZoneGeometry", "zn540", "custom16",
    "PAPER_GEOMETRIES", "MIB", "KIB",
    "ElementKind", "ElementSpec", "ElementLayout", "BLOCK", "SUPERBLOCK",
    "FIXED", "hchunk", "vchunk", "PAPER_ELEMENTS", "build_layout",
    "elements_per_zone", "groups_per_zone", "is_applicable",
    "ZNSDevice", "ZoneState", "ZoneInfo", "IOTrace",
    "DeviceState", "DynConfig", "EngineConfig", "OpTrace", "SpecValues",
    "ZoneEngine", "encode_program", "make_dyn", "make_union_config",
    "stack_dyn",
    "ZoneBackend", "check_backend",
    "select_lowest_wear", "allocate", "RoundRobin", "eligible_mask",
    "alloc_exact", "engine", "metrics", "timing", "workloads", "zns",
]
