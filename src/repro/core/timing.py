"""FEMU-style timing model as a vectorized JAX scan (hardware adaptation).

ConfZNS++/FEMU advance an event-driven clock per flash channel and LUN; we
keep exactly the resources and latencies (program/read/erase/channel
transfer) but execute the request stream as a ``jax.lax.scan`` over
per-resource *busy clocks*:

    start(req)  = max(channel_free[ch], lun_free[lun])
    channel_free[ch] = start + t_xfer
    lun_free[lun]    = start + t_xfer + t_op

This reproduces what the paper measures -- throughput saturation across
parallel units (Fig. 9) and FINISH-vs-host interference (Fig. 4b/7d,
Table 3) -- without NVMe protocol details.  Streams from different actors
(host writers, device FINISH padding) are merged round-robin to model
concurrent submission queues.

Three granularities, coarse to fine:

* :func:`simulate_fleet_ops` -- whole zone ops as single requests, one
  vmapped scan over thousands of (config x device) lanes; the fleet
  allocator search's latency objective.
* :func:`simulate_fleet` / :func:`run_fleet_trace` -- page-granular,
  one vmapped scan per fleet (devices are independent hardware).
* :func:`simulate` / :func:`run_trace` -- page-granular single device,
  the paper-faithful model behind the reported figures.

Units: times in seconds, requests in flash pages (ops/luns/channels are
int32 indexes).
"""

from __future__ import annotations

import functools
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.device import IOTrace
from repro.core.geometry import FlashGeometry

OP_WRITE, OP_READ, OP_ERASE = 0, 1, 2
_OP_CODE = {"write": OP_WRITE, "read": OP_READ, "erase": OP_ERASE}


@functools.partial(jax.jit, static_argnames=("n_luns", "n_channels"))
def simulate(ops: jax.Array, luns: jax.Array, channels: jax.Array,
             t_op: jax.Array, t_xfer: jax.Array,
             n_luns: int, n_channels: int) -> Tuple[jax.Array, jax.Array]:
    """Scan a request stream through per-LUN/per-channel busy clocks.

    Args:
      ops:      (n,) int32 op codes (indexes ``t_op``).
      luns:     (n,) int32 LUN of each request.
      channels: (n,) int32 channel of each request.
      t_op:     (3,) float32 [t_prog, t_read, t_erase].
      t_xfer:   () float32 channel transfer time.

    Returns:
      (completion_times (n,), makespan ()).
    """
    def step(carry, req):
        lun_free, ch_free = carry
        op, lun, ch = req
        start = jnp.maximum(lun_free[lun], ch_free[ch])
        done_xfer = start + t_xfer
        done = done_xfer + t_op[op]
        lun_free = lun_free.at[lun].set(done)
        ch_free = ch_free.at[ch].set(done_xfer)
        return (lun_free, ch_free), done

    init = (jnp.zeros(n_luns, jnp.float32),
            jnp.zeros(n_channels, jnp.float32))
    (lun_free, _), completions = jax.lax.scan(
        step, init, (ops, luns, channels))
    return completions, jnp.max(lun_free)


@functools.partial(jax.jit, static_argnames=("n_luns", "n_channels"))
def simulate_fleet(ops: jax.Array, luns: jax.Array, channels: jax.Array,
                   valid: jax.Array, t_op: jax.Array, t_xfer: jax.Array,
                   n_luns: int, n_channels: int
                   ) -> Tuple[jax.Array, jax.Array]:
    """Batched-device :func:`simulate`: one compiled scan for a fleet.

    Devices are independent hardware, so their busy clocks never interact;
    ``jax.vmap`` over a leading device axis runs all per-device scans in
    one XLA program instead of N sequential dispatches.  Streams of
    unequal length are right-padded; ``valid`` masks padding out of both
    the clocks and the completions.

    Args:
      ops/luns/channels: (n_dev, n) int32, right-padded per device.
      valid:             (n_dev, n) bool, False on padding.
      t_op:              (3,) float32 [t_prog, t_read, t_erase].
      t_xfer:            () float32 channel transfer time.

    Returns:
      (completion_times (n_dev, n) with 0 on padding, makespans (n_dev,)).
    """
    def one_device(ops_d, luns_d, chans_d, valid_d):
        def step(carry, req):
            lun_free, ch_free = carry
            op, lun, ch, ok = req
            start = jnp.maximum(lun_free[lun], ch_free[ch])
            done_xfer = start + t_xfer
            done = done_xfer + t_op[op]
            lun_free = lun_free.at[lun].set(
                jnp.where(ok, done, lun_free[lun]))
            ch_free = ch_free.at[ch].set(
                jnp.where(ok, done_xfer, ch_free[ch]))
            return (lun_free, ch_free), jnp.where(ok, done, 0.0)

        init = (jnp.zeros(n_luns, jnp.float32),
                jnp.zeros(n_channels, jnp.float32))
        (lun_free, _), completions = jax.lax.scan(
            step, init, (ops_d, luns_d, chans_d, valid_d))
        return completions, jnp.max(lun_free)

    return jax.vmap(one_device)(ops, luns, channels, valid)


@functools.partial(jax.jit, static_argnames=("n_luns", "n_tenants"))
def simulate_fleet_ops(cols: jax.Array, pages: jax.Array,
                       tenants: jax.Array, t_page: jax.Array,
                       n_luns: int, n_tenants: int
                       ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Op-granular fleet timing: one batched scan over whole zone ops.

    Where :func:`simulate` advances busy clocks *per page*, this models
    each executed op (a chunk write, FINISH padding burst, parity
    append) as one request occupying all of its zone's LUN columns for
    ``ceil(pages / P) * t_page`` seconds -- the round-robin stripe means
    every column programs ``ceil(pages/P)`` pages back to back.  It is
    the coarse, fully-batched objective the fleet allocator search
    scores thousands of lanes with in a single dispatch; the
    page-granular :func:`run_trace` remains the paper-faithful model
    for reported figures.

    Tenant latency is closed-loop: a tenant issues its next op when its
    previous op completes, so ``latency = completion - previous
    completion of the same tenant`` (queueing + service).

    Args:
      cols:    (n_lanes, n_ops, P) int32 zone column -> LUN of each op
               (from ``OpTrace.cols``).
      pages:   (n_lanes, n_ops) int32 pages the op moved (0 = skip).
      tenants: (n_lanes, n_ops) int32 tenant tag in ``[0, n_tenants)``.
      t_page:  () f32 seconds per page program+transfer, or
               (n_lanes, n_ops) f32 per-op page cost (the array runner
               prices READ rows at ``t_read + t_xfer``).
      n_luns/n_tenants: static sizes.

    Returns:
      (completions (n_lanes, n_ops) f32 with 0 on skipped ops,
       latencies (n_lanes, n_ops) f32, makespans (n_lanes,) f32).
    """
    P = cols.shape[-1]
    t_page = jnp.broadcast_to(
        jnp.asarray(t_page, jnp.float32), pages.shape)

    def one_lane(cols_l, pages_l, ten_l, tp_l):
        def step(carry, x):
            lun_free, ten_done = carry
            c, pg, t, tp = x
            active = pg > 0
            dur = (jnp.ceil(pg / P) * tp).astype(jnp.float32)
            # an op starts when its LUN columns free up AND its tenant
            # has completed its previous op (closed-loop issue)
            start = jnp.maximum(
                jnp.max(jnp.where(active, lun_free[c], 0.0)),
                ten_done[t])
            done = start + dur
            lat = jnp.where(active, done - ten_done[t], 0.0)
            lun_free = lun_free.at[c].set(
                jnp.where(active, done, lun_free[c]))
            ten_done = ten_done.at[t].set(
                jnp.where(active, done, ten_done[t]))
            return (lun_free, ten_done), (jnp.where(active, done, 0.0), lat)

        init = (jnp.zeros(n_luns, jnp.float32),
                jnp.zeros(n_tenants, jnp.float32))
        (lun_free, _), (done, lat) = jax.lax.scan(
            step, init, (cols_l, pages_l, ten_l, tp_l))
        return done, lat, jnp.max(lun_free)

    return jax.vmap(one_lane)(cols, pages, tenants, t_page)


def run_fleet_trace(flash: FlashGeometry,
                    device_traces: Sequence[Sequence[IOTrace]],
                    *, interleave: bool = True) -> dict:
    """Simulate per-device trace bundles in one vmapped scan.

    ``device_traces[i]`` holds device ``i``'s concurrent streams (host
    data chunks, parity appends routed to it, FINISH padding); each
    device's streams are merged round-robin (cross-device merge for
    parity traffic) exactly as :func:`run_trace` would, then all devices
    advance together under :func:`simulate_fleet`.

    Returns per-device makespans/throughputs plus the fleet makespan
    (the slowest member -- the array completes a stripe only when every
    chunk, parity included, is durable).
    """
    n_dev = len(device_traces)
    if n_dev == 0:
        return {"fleet_makespan_s": 0.0, "n": 0}
    merged = []
    for trs in device_traces:
        trs = [t for t in trs if len(t.luns)]
        if trs:
            ops, luns, chans, _ = _merge(trs, interleave)
        else:
            ops = luns = chans = np.zeros(0, dtype=np.int32)
        merged.append((ops, luns, chans))
    n_max = max(1, max(len(m[0]) for m in merged))

    def pad(a: np.ndarray) -> np.ndarray:
        out = np.zeros(n_max, dtype=np.int32)
        out[: len(a)] = a
        return out

    ops = np.stack([pad(m[0]) for m in merged])
    luns = np.stack([pad(m[1]) for m in merged])
    chans = np.stack([pad(m[2]) for m in merged])
    valid = np.stack([np.arange(n_max) < len(m[0]) for m in merged])
    t_op = jnp.asarray([flash.t_prog, flash.t_read, flash.t_erase],
                       jnp.float32)
    completions, makespans = simulate_fleet(
        jnp.asarray(ops), jnp.asarray(luns), jnp.asarray(chans),
        jnp.asarray(valid), t_op, jnp.asarray(flash.t_xfer, jnp.float32),
        flash.n_luns, flash.n_channels)
    makespans = np.asarray(makespans)
    counts = valid.sum(axis=1)
    out = {"fleet_makespan_s": float(makespans.max()),
           "n": int(counts.sum())}
    for i in range(n_dev):
        t = float(makespans[i])
        out[f"dev{i}_makespan_s"] = t
        out[f"dev{i}_n"] = int(counts[i])
        out[f"dev{i}_throughput_pages_s"] = float(counts[i] / t) if t else 0.0
    return out


def group_tagged(tagged: Sequence[Tuple[int, IOTrace]], n_devices: int
                 ) -> list:
    """Split ``(device, trace)`` pairs (as emitted by ``ZNSArray`` trace
    mode) into the per-device bundles ``run_fleet_trace`` consumes."""
    out: list = [[] for _ in range(n_devices)]
    for idx, tr in tagged:
        out[idx].append(tr)
    return out


def run_trace(flash: FlashGeometry, traces: Sequence[IOTrace],
              *, interleave: bool = True) -> dict:
    """Simulate one or more IOTraces; returns timing stats.

    ``interleave=True`` merges the traces round-robin (concurrent queues);
    ``False`` concatenates them (sequential submission).
    """
    if not traces:
        return {"makespan_s": 0.0, "n": 0, "throughput_pages_s": 0.0}
    ops, luns, chans, owner = _merge(traces, interleave)
    t_op = jnp.asarray([flash.t_prog, flash.t_read, flash.t_erase],
                       jnp.float32)
    completions, makespan = simulate(
        jnp.asarray(ops), jnp.asarray(luns), jnp.asarray(chans),
        t_op, jnp.asarray(flash.t_xfer, jnp.float32),
        flash.n_luns, flash.n_channels)
    completions = np.asarray(completions)
    makespan = float(makespan)
    out = {"makespan_s": makespan, "n": int(len(ops)),
           "throughput_pages_s": len(ops) / makespan if makespan else 0.0}
    # per-owner completion (owner 0 = first trace = usually the host)
    for i in range(len(traces)):
        sel = owner == i
        if sel.any():
            t = float(completions[sel].max())
            out[f"owner{i}_makespan_s"] = t
            out[f"owner{i}_throughput_pages_s"] = int(sel.sum()) / t if t else 0.0
    return out


def _merge(traces: Sequence[IOTrace], interleave: bool
           ) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    ops_l, luns_l, chans_l, owner_l = [], [], [], []
    for i, tr in enumerate(traces):
        n = len(tr.luns)
        ops_l.append(np.full(n, _OP_CODE[tr.op], dtype=np.int32))
        luns_l.append(np.asarray(tr.luns, dtype=np.int32))
        chans_l.append(np.asarray(tr.channels, dtype=np.int32))
        owner_l.append(np.full(n, i, dtype=np.int32))
    if not interleave or len(traces) == 1:
        return (np.concatenate(ops_l), np.concatenate(luns_l),
                np.concatenate(chans_l), np.concatenate(owner_l))
    # round-robin merge by per-stream position (models concurrent queues)
    order_keys = np.concatenate(
        [np.arange(len(t.luns), dtype=np.int64) * len(traces) + i
         for i, t in enumerate(traces)])
    perm = np.argsort(order_keys, kind="stable")
    return (np.concatenate(ops_l)[perm], np.concatenate(luns_l)[perm],
            np.concatenate(chans_l)[perm], np.concatenate(owner_l)[perm])


def write_bandwidth_mib_s(flash: FlashGeometry, stats: dict,
                          owner: int | None = None) -> float:
    key = ("throughput_pages_s" if owner is None
           else f"owner{owner}_throughput_pages_s")
    return stats.get(key, 0.0) * flash.page_bytes / (1024 * 1024)
