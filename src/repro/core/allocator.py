"""Vectorized JAX zone allocator (paper §5, Eqs. 1-6).

All element layouts in :mod:`repro.core.elements` are *group-major with a
fixed per-group count*, so the allocator views the device as a dense
``(n_groups, per_group)`` wear/availability matrix and the balanced ILP
solution is a masked per-row top-G selection:

    for each eligible group g: take the ``take`` lowest-wear available
    elements of row g.

This is exactly the computation the Pallas ``zns_alloc`` kernel implements
on TPU (rows tiled into VMEM); here we provide the jit'd XLA fallback that
the emulator uses on CPU, plus the round-robin eligible-group rotation the
paper uses to spread consecutive zones across LUNs (Eq. 6).

The general (unbalanced) ILP is handled by :mod:`repro.core.alloc_exact`;
hypothesis tests assert this fast path matches the exact DP wherever the
balanced form applies (every configuration evaluated in the paper).
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.alloc_exact import ALLOCATABLE

_BIG = jnp.array(2**30, jnp.int32)  # sentinel wear for unavailable slots


@functools.partial(jax.jit, static_argnames=("take",))
def select_lowest_wear(wear2d: jax.Array,
                       avail2d: jax.Array,
                       eligible: jax.Array,
                       take: int) -> Tuple[jax.Array, jax.Array]:
    """Masked per-group lowest-wear selection.

    Args:
      wear2d:   (n_groups, per_group) int32 erase counts.
      avail2d:  (n_groups, per_group) int32 availability codes.
      eligible: (n_groups,) bool -- groups allowed to contribute (Eq. 6).
      take:     elements to take per eligible group (static).

    Returns:
      sel:      (n_groups, per_group) bool selection mask.
      feasible: () bool -- every eligible group had >= take available.
    """
    allocatable = (avail2d == ALLOCATABLE[0]) | (avail2d == ALLOCATABLE[1])
    allocatable = allocatable & eligible[:, None]
    keyed = jnp.where(allocatable, wear2d, _BIG)
    # rank of each slot within its row by (wear, index) -- stable
    order = jnp.argsort(keyed, axis=1, stable=True)
    ranks = jnp.argsort(order, axis=1, stable=True)
    sel = (ranks < take) & allocatable
    feasible = jnp.all(jnp.where(eligible,
                                 jnp.sum(allocatable, axis=1) >= take,
                                 True))
    return sel, feasible


@functools.partial(jax.jit, static_argnames=("take",))
def selection_cost(wear2d: jax.Array, sel: jax.Array, take: int) -> jax.Array:
    del take
    return jnp.sum(jnp.where(sel, wear2d, 0))


def eligible_mask(n_groups: int, start: int, span: int) -> np.ndarray:
    """Round-robin eligible-group window (paper Eq. 6): ``span`` adjacent
    groups starting at ``start`` (mod n_groups)."""
    idx = (start + np.arange(span)) % n_groups
    mask = np.zeros(n_groups, dtype=bool)
    mask[idx] = True
    return mask


class RoundRobin:
    """Rotates the eligible-group window between consecutive allocations so
    consecutive zones land on disjoint LUNs where possible (paper §5)."""

    def __init__(self, n_groups: int, span: int):
        if span > n_groups:
            raise ValueError(f"span {span} > n_groups {n_groups}")
        self.n_groups = n_groups
        self.span = span
        self._next = 0

    def next_window(self) -> np.ndarray:
        mask = eligible_mask(self.n_groups, self._next, self.span)
        self._next = (self._next + self.span) % self.n_groups
        return mask

    def reset(self) -> None:
        self._next = 0


def allocate(wear2d: np.ndarray,
             avail2d: np.ndarray,
             eligible: np.ndarray,
             take: int,
             *,
             impl: str = "xla") -> Tuple[np.ndarray, bool]:
    """Host-facing allocation entry point.

    ``impl``: 'xla' (jit fallback) or 'pallas' (TPU kernel via
    :mod:`repro.kernels.zns_alloc.ops`, interpret-mode on CPU).
    Returns (selection mask (n_groups, per_group), feasible).
    """
    if impl == "pallas":
        from repro.kernels.zns_alloc import ops as _ops
        sel, feasible = _ops.zns_alloc(
            jnp.asarray(wear2d, jnp.int32),
            jnp.asarray(avail2d, jnp.int32),
            jnp.asarray(eligible),
            take=take)
    else:
        sel, feasible = select_lowest_wear(
            jnp.asarray(wear2d, jnp.int32),
            jnp.asarray(avail2d, jnp.int32),
            jnp.asarray(eligible),
            take=take)
    return np.asarray(sel), bool(feasible)
