"""Storage elements: the zone-allocation granularity axis (paper §4, Table 1).

A *storage element* is the smallest unit that is FINISHed and RESET as a
whole.  The paper's five element kinds, over a device of L LUNs with B
erase blocks each:

=============  =====================================  ==================
kind           definition                             #elements
=============  =====================================  ==================
BLOCK          one erase block                        L * B
HCHUNK(s)      s consecutive blocks within one LUN    L * B / s
VCHUNK(s)      s blocks, same offset, s adjacent LUNs (L/s) * B
SUPERBLOCK     VCHUNK(L): one block per LUN           B
FIXED          the entire (static) physical zone      n_zones
=============  =====================================  ==================

Element ids are dense in ``[0, n_elements)``.  Every element knows its
*column group* (which LUN-columns it occupies) so the allocator can enforce
the paper's zone-parallelism constraints (Eqs. 3-6), and its *blocks* so
the device can account wear and dummy-pad writes per erase block.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Tuple

import numpy as np

from repro.core.geometry import FlashGeometry, ZoneGeometry


class ElementKind(enum.Enum):
    BLOCK = "block"
    HCHUNK = "hchunk"
    VCHUNK = "vchunk"
    SUPERBLOCK = "superblock"
    FIXED = "fixed"  # ConfZNS++ baseline: static physical zones


@dataclasses.dataclass(frozen=True)
class ElementSpec:
    kind: ElementKind
    chunk: int = 1  # s for HCHUNK/VCHUNK; ignored otherwise

    @property
    def name(self) -> str:
        if self.kind in (ElementKind.HCHUNK, ElementKind.VCHUNK):
            return f"{self.kind.value}{self.chunk}"
        return self.kind.value


BLOCK = ElementSpec(ElementKind.BLOCK)
SUPERBLOCK = ElementSpec(ElementKind.SUPERBLOCK)
FIXED = ElementSpec(ElementKind.FIXED)


def hchunk(s: int) -> ElementSpec:
    return ElementSpec(ElementKind.HCHUNK, s)


def vchunk(s: int) -> ElementSpec:
    return ElementSpec(ElementKind.VCHUNK, s)


#: Paper §6.1 "Zone Storage Elements": fixed, superblock, block, Vchunk-2,
#: Vchunk-4, Hchunk-2.
PAPER_ELEMENTS: Tuple[ElementSpec, ...] = (
    FIXED,
    SUPERBLOCK,
    BLOCK,
    vchunk(2),
    vchunk(4),
    hchunk(2),
)


@dataclasses.dataclass(frozen=True)
class ElementLayout:
    """Dense description of all storage elements of one kind on a device.

    Arrays (all length ``n_elements`` unless noted):

    * ``group``       -- the element's LUN-group index in ``[0, n_groups)``.
      For BLOCK/HCHUNK a group is a single LUN; for VCHUNK(s) a group is a
      band of s adjacent LUNs; for SUPERBLOCK there is one group (all LUNs).
    * ``blocks``      -- (n_elements, blocks_per_element) global block ids.
    * ``blocks_per_element`` / ``pages_per_element`` -- scalars.
    * ``n_groups``    -- number of LUN-groups the allocator chooses among.
    * ``luns_per_group`` -- LUN columns per group (parallelism contributed
      by a single element).
    """

    spec: ElementSpec
    n_elements: int
    n_groups: int
    luns_per_group: int
    blocks_per_element: int
    pages_per_element: int
    group: np.ndarray   # (n_elements,) int32
    blocks: np.ndarray  # (n_elements, blocks_per_element) int32

    def elements_in_group(self, g: int) -> np.ndarray:
        return np.nonzero(self.group == g)[0]


def build_layout(flash: FlashGeometry, spec: ElementSpec,
                 zone: ZoneGeometry | None = None) -> ElementLayout:
    """Construct the element layout for ``spec`` on ``flash``.

    ``zone`` is required for FIXED (the element *is* a static zone).
    Blocks are numbered LUN-major: ``block = lun * B + off``.
    """
    L, B = flash.n_luns, flash.blocks_per_lun
    ppb = flash.pages_per_block

    if spec.kind is ElementKind.BLOCK:
        n_elem = L * B
        # element id e = lun * B + off  (same as global block id)
        group = (np.arange(n_elem, dtype=np.int32) // B).astype(np.int32)
        blocks = np.arange(n_elem, dtype=np.int32)[:, None]
        return ElementLayout(spec, n_elem, L, 1, 1, ppb, group, blocks)

    if spec.kind is ElementKind.HCHUNK:
        s = spec.chunk
        if B % s:
            raise ValueError(f"hchunk size {s} must divide blocks_per_lun {B}")
        n_per_lun = B // s
        n_elem = L * n_per_lun
        eids = np.arange(n_elem, dtype=np.int32)
        lun = eids // n_per_lun
        within = eids % n_per_lun
        group = lun.astype(np.int32)
        # s consecutive blocks within the LUN
        base = lun * B + within * s
        blocks = (base[:, None] + np.arange(s, dtype=np.int32)[None, :]).astype(np.int32)
        return ElementLayout(spec, n_elem, L, 1, s, s * ppb, group, blocks)

    if spec.kind in (ElementKind.VCHUNK, ElementKind.SUPERBLOCK):
        s = L if spec.kind is ElementKind.SUPERBLOCK else spec.chunk
        if L % s:
            raise ValueError(f"vchunk size {s} must divide n_luns {L}")
        n_groups = L // s
        n_elem = n_groups * B
        eids = np.arange(n_elem, dtype=np.int32)
        grp = eids // B          # LUN band
        off = eids % B           # block offset within every LUN of the band
        group = grp.astype(np.int32)
        luns = grp[:, None] * s + np.arange(s, dtype=np.int32)[None, :]
        blocks = (luns * B + off[:, None]).astype(np.int32)
        return ElementLayout(spec, n_elem, n_groups, s, s, s * ppb, group, blocks)

    if spec.kind is ElementKind.FIXED:
        if zone is None:
            raise ValueError("FIXED layout needs the zone geometry")
        P, G = zone.parallelism, zone.n_segments
        if L % P:
            raise ValueError(f"zone parallelism {P} must divide n_luns {L}")
        bands = L // P                    # vertical placement choices
        zones_per_band = B // G           # stacked zones within a band
        n_elem = bands * zones_per_band
        eids = np.arange(n_elem, dtype=np.int32)
        # band-interleaved numbering: consecutive physical zones land on
        # different LUN bands so concurrent writers scale (paper Fig. 9)
        band = eids % bands
        stack = eids // bands
        group = band.astype(np.int32)
        luns = band[:, None, None] * P + np.arange(P, dtype=np.int32)[None, :, None]
        offs = stack[:, None, None] * G + np.arange(G, dtype=np.int32)[None, None, :]
        blocks = (luns * B + offs).reshape(n_elem, P * G).astype(np.int32)
        return ElementLayout(spec, n_elem, bands, P, P * G, P * G * ppb,
                             group, blocks)

    raise ValueError(f"unknown element kind: {spec.kind}")


def elements_per_zone(layout: ElementLayout, zone: ZoneGeometry) -> int:
    """How many elements of this kind compose one zone."""
    if layout.spec.kind is ElementKind.FIXED:
        return 1
    total_blocks = zone.blocks_per_zone
    if total_blocks % layout.blocks_per_element:
        raise ValueError(
            f"zone of {total_blocks} blocks not divisible by element "
            f"{layout.spec.name} ({layout.blocks_per_element} blocks)")
    return total_blocks // layout.blocks_per_element


def groups_per_zone(layout: ElementLayout, zone: ZoneGeometry) -> int:
    """How many LUN-groups a zone's elements must span (the paper's
    parallelism constraint, adapted to the element granularity)."""
    if layout.spec.kind is ElementKind.FIXED:
        return 1
    if layout.luns_per_group > zone.parallelism:
        raise ValueError(
            f"element {layout.spec.name} spans {layout.luns_per_group} LUNs "
            f"> zone parallelism {zone.parallelism}")
    if zone.parallelism % layout.luns_per_group:
        raise ValueError(
            f"zone parallelism {zone.parallelism} not divisible by element "
            f"span {layout.luns_per_group}")
    return zone.parallelism // layout.luns_per_group


def union_grid_ids(n_elements: int, per_group: int,
                   grid_per_group: int) -> np.ndarray:
    """Dense element ids of one union member -> union-grid positions.

    A padded union layout (one static config hosting several element
    specs per lane) stores member element ``(g, c)`` at grid id
    ``g * grid_per_group + c``; for members whose group width equals
    the grid's (BLOCK / VCHUNK / SUPERBLOCK all share
    ``per_group = blocks_per_lun``) this is the identity prefix.
    """
    ids = np.arange(n_elements, dtype=np.int64)
    return (ids // per_group) * grid_per_group + ids % per_group


def union_grid_mask(grid_n_elements: int, grid_per_group: int,
                    n_elements, per_group) -> np.ndarray:
    """Boolean mask of the union grid's *real* cells for one member
    spec (or, with ``(L,)`` arrays, one row per batch lane): groups
    below ``n_elements // per_group`` and columns below ``per_group``;
    everything else is padding the allocator never touches."""
    ids = np.arange(grid_n_elements, dtype=np.int64)
    g, c = ids // grid_per_group, ids % grid_per_group
    ne = np.asarray(n_elements, dtype=np.int64)
    pg = np.asarray(per_group, dtype=np.int64)
    if ne.ndim:
        g, c, ne, pg = g[None, :], c[None, :], ne[:, None], pg[:, None]
    return (g < ne // pg) & (c < pg)


def is_applicable(spec: ElementSpec, zone: ZoneGeometry, flash: FlashGeometry) -> bool:
    """Paper Tables 3-4 mark some (geometry, element) cells N/A:
    superblock needs P == L; hchunk-s needs n_segments % s == 0 (an hchunk
    sits vertically across segments of one column)."""
    try:
        if spec.kind is ElementKind.SUPERBLOCK:
            return zone.parallelism == flash.n_luns
        if spec.kind is ElementKind.HCHUNK:
            return zone.n_segments % spec.chunk == 0
        if spec.kind is ElementKind.VCHUNK:
            return (zone.parallelism % spec.chunk == 0
                    and flash.n_luns % spec.chunk == 0)
        return True
    except Exception:
        return False
